//! Tiny JSON emitter (serde is unavailable offline).
//!
//! Experiment drivers dump their series as JSON so EXPERIMENTS.md numbers are
//! regenerable and diffable. Only emission is needed — configs are TOML
//! (see `config::toml`), results are JSON.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. `BTreeMap` keeps key order deterministic across runs.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn set(&mut self, key: &str, val: Json) -> &mut Self {
        if let Json::Obj(m) = self {
            m.insert(key.to_string(), val);
        } else {
            panic!("Json::set on non-object");
        }
        self
    }

    pub fn num(x: f64) -> Json {
        Json::Num(x)
    }

    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }

    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    pub fn arr_str(xs: &[String]) -> Json {
        Json::Arr(xs.iter().map(|x| Json::Str(x.clone())).collect())
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.is_finite() {
                    if *x == x.trunc() && x.abs() < 1e15 {
                        let _ = write!(out, "{}", *x as i64);
                    } else {
                        let _ = write!(out, "{x}");
                    }
                } else {
                    out.push_str("null"); // JSON has no NaN/Inf
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(xs) => {
                out.push('[');
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_object() {
        let mut j = Json::obj();
        j.set("name", Json::str("fig4"))
            .set("values", Json::arr_f64(&[1.0, 2.5]))
            .set("ok", Json::Bool(true));
        assert_eq!(
            j.to_string(),
            r#"{"name":"fig4","ok":true,"values":[1,2.5]}"#
        );
    }

    #[test]
    fn escapes() {
        assert_eq!(Json::str("a\"b\n").to_string(), r#""a\"b\n""#);
    }

    #[test]
    fn nan_becomes_null() {
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
    }

    #[test]
    fn integers_render_without_fraction() {
        assert_eq!(Json::Num(42.0).to_string(), "42");
        assert_eq!(Json::Num(0.125).to_string(), "0.125");
    }
}
