//! Experiment drivers — one per table/figure of the paper (DESIGN.md
//! "Experiment index"). Shared by the CLI (`pingan figure ...`), the
//! benches, and the examples. The grid-shaped experiments are thin
//! [`crate::sweep::SweepSpec`] constructions over the parallel sweep
//! runner; this module keeps only the scale presets and the single-run
//! helpers (`sim_setup`/`run_one`) the CLI's one-off `simulate` uses.

pub mod figures;
pub mod tables;

use crate::cluster::GeoSystem;
use crate::config::spec::{Allocation, Principle, ScorerKind, SystemSpec, WorkloadSpec};
use crate::sched::Scheduler;
use crate::simulator::{SimConfig, SimResult, Simulation};
use crate::sweep::Scenario;
use crate::util::rng::Rng;
use crate::workload::{job::JobSpec, montage};

/// Experiment scale: defaults are a reduced-but-same-shape reproduction;
/// `Scale::paper()` restores the paper's numbers (2000 workflows, 100
/// clusters, 10 repetitions — hours of wall time).
#[derive(Clone, Copy, Debug)]
pub struct Scale {
    pub n_clusters: usize,
    pub n_jobs: usize,
    pub reps: u64,
    /// Shrink per-cluster VM counts by this divisor (keeps load comparable
    /// when n_jobs shrinks).
    pub slot_divisor: u64,
}

impl Scale {
    pub fn default_repro() -> Scale {
        Scale {
            n_clusters: 30,
            n_jobs: 160,
            reps: 2,
            slot_divisor: 4,
        }
    }

    pub fn smoke() -> Scale {
        Scale {
            n_clusters: 8,
            n_jobs: 16,
            reps: 1,
            slot_divisor: 10,
        }
    }

    pub fn paper() -> Scale {
        Scale {
            n_clusters: 100,
            n_jobs: 2000,
            reps: 10,
            slot_divisor: 1,
        }
    }

    /// The plant spec at this scale — delegates to the sweep scenario so
    /// `pingan simulate` and sweep cells at the same coordinates shrink
    /// the plant identically.
    pub fn system_spec(&self, seed: u64) -> SystemSpec {
        base_scenario(self).system_spec(seed)
    }
}

/// Scheduler factory — names match the paper's figures. Thin panicking
/// wrapper over [`crate::sweep::make_scheduler`] for call sites that treat
/// a bad name as a programming error. Uses the default (batched CPU)
/// scorer; pass a [`ScorerKind`] through the sweep factory to vary it.
pub fn make_scheduler(name: &str, epsilon: f64) -> Box<dyn Scheduler> {
    match crate::sweep::make_scheduler(
        name,
        epsilon,
        Principle::EffReli,
        Allocation::Efa,
        ScorerKind::Cpu,
    ) {
        Ok(s) => s,
        Err(e) => panic!("{e}"),
    }
}

/// The base sweep scenario matching a [`Scale`] preset.
pub fn base_scenario(scale: &Scale) -> Scenario {
    let mut s = Scenario::default();
    s.n_clusters = scale.n_clusters;
    s.n_jobs = scale.n_jobs;
    s.slot_divisor = scale.slot_divisor;
    s
}

pub const SIM_BASELINES: [&str; 4] = ["flutter", "iridium", "flutter+mantri", "flutter+dolly"];

/// Build (system, montage workload) for one repetition.
///
/// `lambda` is quoted at *paper* scale (100 full-size clusters); when the
/// plant is shrunk by `slot_divisor`, the arrival rate shrinks with it so
/// the offered-load ratio (arrival work per slot of capacity) matches the
/// paper's λ — otherwise the reduced plant would saturate at nominal λ.
pub fn sim_setup(scale: &Scale, lambda: f64, rep: u64) -> (GeoSystem, Vec<JobSpec>) {
    let seed = 0x5EED_0000 + rep * 7919;
    let mut rng = Rng::new(seed);
    let sys = GeoSystem::generate(&scale.system_spec(seed), &mut rng);
    let effective_lambda = lambda / scale.slot_divisor.max(1) as f64;
    let mut w = WorkloadSpec::scaled(scale.n_jobs, effective_lambda);
    w.seed = seed ^ 0xABCD;
    // inputs scattered over edges and some medium clusters (Sec 6.1)
    let sites: Vec<usize> = (0..sys.n()).collect();
    let jobs = montage::generate(&w, &sites, &mut rng);
    (sys, jobs)
}

/// Run one scheduler over one setup.
pub fn run_one(sys: &GeoSystem, jobs: Vec<JobSpec>, name: &str, epsilon: f64, rep: u64) -> SimResult {
    let mut cfg = SimConfig::default();
    cfg.seed = 0xC0FFEE ^ rep;
    let mut sched = make_scheduler(name, epsilon);
    Simulation::new(sys, jobs, cfg).run(sched.as_mut())
}

/// Average per-job flowtimes across repetitions: the paper runs each
/// workload ten times and averages per job. Returns per-job means.
pub fn averaged_flowtimes(results: &[SimResult]) -> Vec<f64> {
    assert!(!results.is_empty());
    let series: Vec<&[f64]> = results.iter().map(|r| r.flowtimes.as_slice()).collect();
    crate::metrics::average_per_job(&series)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn factory_covers_all_names() {
        for n in SIM_BASELINES.iter().chain(&["pingan", "spark", "spark-spec"]) {
            let s = make_scheduler(n, 0.6);
            assert!(!s.name().is_empty());
        }
    }

    #[test]
    #[should_panic]
    fn factory_rejects_unknown() {
        make_scheduler("nope", 0.5);
    }

    #[test]
    fn averaging_skips_nan() {
        let mk = |flows: Vec<f64>| SimResult::synthetic("x", flows);
        let avg = averaged_flowtimes(&[mk(vec![10.0, f64::NAN]), mk(vec![20.0, 30.0])]);
        assert_eq!(avg[0], 15.0);
        assert_eq!(avg[1], 30.0);
    }

    #[test]
    fn smoke_setup_runs_fast() {
        let scale = Scale::smoke();
        let (sys, jobs) = sim_setup(&scale, 0.05, 0);
        assert_eq!(jobs.len(), scale.n_jobs);
        let res = run_one(&sys, jobs, "flutter", 0.6, 0);
        assert_eq!(res.finished_jobs, res.total_jobs);
    }
}
