"""Pallas kernel: bottleneck (min) composition of two distributions.

A copy's execution rate is ``min(V^P, V^T)`` (paper Sec 3.2). On a shared
grid the pmf of the min is

    p_min[j] = p[j]·P(T > v_j) + t[j]·P(P > v_j) + p[j]·t[j]

with the exclusive survival functions computed as reversed cumulative
sums. Shapes: two [B, K, V] pmf tensors -> [B, K, V] pmf of the min,
renormalized against numeric drift.

TPU shaping: grid over B, [K, V] block resident in VMEM; the reversed
cumsum is a lane-dimension scan, the rest is elementwise — no MXU use,
bandwidth-bound, which is why the AOT artifact fuses this with `expmax`
into one module (`score`) so the intermediate pmf never round-trips HBM.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _bottleneck_kernel(proc_ref, trans_ref, out_ref):
    p = proc_ref[...]  # [1, K, V]
    t = trans_ref[...]
    sf_p = jnp.cumsum(p[..., ::-1], axis=-1)[..., ::-1] - p
    sf_t = jnp.cumsum(t[..., ::-1], axis=-1)[..., ::-1] - t
    out = p * sf_t + t * sf_p + p * t
    total = jnp.sum(out, axis=-1, keepdims=True)
    out_ref[...] = out / jnp.maximum(total, 1e-30)


def bottleneck(proc_pmf, trans_pmf, *, interpret=True):
    """pmf of min(P, T): [B,K,V] × [B,K,V] -> [B,K,V]."""
    b, k, v = proc_pmf.shape
    return pl.pallas_call(
        _bottleneck_kernel,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, k, v), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, k, v), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, k, v), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, k, v), proc_pmf.dtype),
        interpret=interpret,
    )(proc_pmf, trans_pmf)
