//! Flutter + Dolly (Ananthanarayanan et al. — NSDI'13): proactive cloning.
//! Small jobs — where a single straggler dominates flowtime — get every
//! task cloned at launch, within a spare-resource budget; clone counts
//! shrink as jobs grow (Dolly's insight: cloning is cheap exactly for the
//! many small jobs).

use super::flutter::Flutter;
use crate::sched::{Action, Assignment, SchedView, Scheduler};

/// Fraction of total slots Dolly may use for clones (the paper's budget β).
const CLONE_BUDGET: f64 = 0.20;

pub struct Dolly {
    /// Set when the last epoch launched primaries: their clones become
    /// placeable only once the tasks are Running, i.e. next slot — the
    /// event-skip core gets asked for an epoch there.
    clones_pending: bool,
}

impl Dolly {
    pub fn new() -> Dolly {
        Dolly {
            clones_pending: false,
        }
    }

    /// Clone count per task by job size (including the primary copy) —
    /// Dolly's insight: the many small jobs are cheap to clone whole.
    fn clones_for(n_tasks: usize) -> usize {
        if n_tasks <= 20 {
            3
        } else if n_tasks <= 150 {
            2
        } else {
            1
        }
    }
}

impl Default for Dolly {
    fn default() -> Self {
        Self::new()
    }
}

impl Scheduler for Dolly {
    fn name(&self) -> &str {
        "flutter+dolly"
    }

    fn schedule(&mut self, view: &mut SchedView<'_>) -> Vec<Action> {
        let mut out = Vec::new();
        let total = view.system.total_slots();
        let mut order: Vec<usize> = view.alive.to_vec();
        order.sort_by_key(|&ji| view.jobs[ji].spec.arrival);
        // primary copies via Flutter placement
        for &ji in &order {
            for ti in view.ready_tasks(ji) {
                Flutter::place(view, ji, ti, &mut out);
            }
        }
        self.clones_pending = !out.is_empty();
        // clone pass within spare budget
        let mut budget =
            ((total as f64 * CLONE_BUDGET) as usize).min(view.total_free());
        for &ji in &order {
            if budget == 0 {
                break;
            }
            let want = Self::clones_for(view.jobs[ji].spec.n_tasks());
            if want <= 1 {
                continue;
            }
            for ti in view.running_tasks(ji) {
                if budget == 0 {
                    break;
                }
                let rt = &view.jobs[ji].tasks[ti];
                if rt.alive_copies() >= want {
                    continue;
                }
                let sources = rt.sources.clone();
                let op = view.jobs[ji].spec.tasks[ti].op;
                let occupied = rt.copy_clusters();
                // clone on the best free cluster not already hosting a copy
                let mut best: Option<(f64, usize)> = None;
                for m in 0..view.system.n() {
                    if view.free_slots[m] == 0 || occupied.contains(&m) {
                        continue;
                    }
                    let r = view.model.exp_rate1(&sources, m, op);
                    if best.map(|(b, _)| r > b).unwrap_or(true) {
                        best = Some((r, m));
                    }
                }
                if let Some((r, m)) = best {
                    if view.try_reserve_slot(m) {
                        if view.try_reserve_bandwidth_full(&sources, m, r) {
                            out.push(Action::Launch(Assignment {
                                job: ji,
                                task: ti,
                                cluster: m,
                            }));
                            budget -= 1;
                        } else {
                            view.free_slots[m] += 1;
                        }
                    }
                }
            }
        }
        out
    }

    fn next_wake(&mut self, now: u64) -> Option<u64> {
        self.clones_pending.then_some(now + 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::GeoSystem;
    use crate::config::spec::{SystemSpec, WorkloadSpec};
    use crate::simulator::{SimConfig, Simulation};
    use crate::util::rng::Rng;
    use crate::workload::montage;

    #[test]
    fn clone_counts_shrink_with_job_size() {
        assert_eq!(Dolly::clones_for(5), 3);
        assert_eq!(Dolly::clones_for(80), 2);
        assert_eq!(Dolly::clones_for(500), 1);
    }

    #[test]
    fn dolly_clones_small_jobs() {
        let mut rng = Rng::new(84);
        let sys = GeoSystem::generate(&SystemSpec::small(6), &mut rng);
        let mut w = WorkloadSpec::scaled(10, 0.03);
        w.datasize = (50.0, 300.0);
        // force small jobs so cloning triggers
        w.size_classes = vec![(1.0, (2, 8))];
        let sites: Vec<usize> = (0..sys.n()).collect();
        let jobs = montage::generate(&w, &sites, &mut rng);
        let n_tasks: u64 = jobs.iter().map(|j| j.n_tasks() as u64).sum();
        let res = Simulation::new(&sys, jobs, SimConfig::default()).run(&mut Dolly::new());
        assert_eq!(res.finished_jobs, res.total_jobs);
        assert!(
            res.copies_launched > n_tasks,
            "expected clones: {} for {} tasks",
            res.copies_launched,
            n_tasks
        );
    }
}
