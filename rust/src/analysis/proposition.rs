//! Proposition 1 and Theorem 2 numeric checks.

use crate::dist::{Grid, Hist};
use crate::util::rng::Rng;

/// Check Proposition 1 on one family of copy-rate distributions: when
/// copies are added best-first (descending mean — PingAn greedily insures
/// the best available copy each round), `r(k)/k` must be non-increasing.
///
/// Returns the sequence of ratios; `Err` with the violating index if the
/// property fails beyond `tol`.
pub fn check_proposition1(hists: &[Hist], tol: f64) -> Result<Vec<f64>, usize> {
    assert!(!hists.is_empty());
    // best-first ordering by mean
    let mut order: Vec<usize> = (0..hists.len()).collect();
    order.sort_by(|&a, &b| hists[b].mean().partial_cmp(&hists[a].mean()).unwrap());
    let mut ratios = Vec::with_capacity(hists.len());
    let mut prev = f64::INFINITY;
    for k in 1..=hists.len() {
        let refs: Vec<&Hist> = order[..k].iter().map(|&i| &hists[i]).collect();
        let r = Hist::expected_max(&refs) / k as f64;
        if r > prev + tol {
            return Err(k);
        }
        ratios.push(r);
        prev = r;
    }
    Ok(ratios)
}

/// Random family generator for property checks.
pub fn random_family(rng: &mut Rng, n: usize, grid: &Grid) -> Vec<Hist> {
    (0..n)
        .map(|_| {
            let mean = rng.range_f64(1.0, 9.0);
            let std = rng.range_f64(0.2, 2.5);
            Hist::normal(grid, mean, std)
        })
        .collect()
}

/// Theorem 2's competitive-ratio expression with speed augmentation 1+ε:
/// `(α(1+ε) + C) / (αε² + (α−1)ε)` where α > 1/(1+ε) is the rate-floor
/// fraction and C the adversary's max copy count.
pub fn competitive_ratio(epsilon: f64, alpha: f64, c_max: f64) -> f64 {
    assert!(epsilon > 0.0 && epsilon < 1.0);
    assert!(
        alpha > 1.0 / (1.0 + epsilon),
        "alpha must exceed 1/(1+eps) for the bound to hold"
    );
    (alpha * (1.0 + epsilon) + c_max) / (alpha * epsilon * epsilon + (alpha - 1.0) * epsilon)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn proposition1_holds_on_random_families() {
        let grid = Grid::uniform(0.0, 10.0, 96);
        let mut rng = Rng::new(101);
        for trial in 0..50 {
            let fam = random_family(&mut rng, 6, &grid);
            let ratios = check_proposition1(&fam, 1e-9)
                .unwrap_or_else(|k| panic!("trial {trial}: violated at k={k}"));
            assert_eq!(ratios.len(), 6);
            // r(1) is the best single mean
            let best = fam
                .iter()
                .map(|h| h.mean())
                .fold(f64::NEG_INFINITY, f64::max);
            assert!((ratios[0] - best).abs() < 1e-9);
        }
    }

    #[test]
    fn proposition1_catches_violations() {
        // hand-built violation: r(2)/2 > r(1)/1 is impossible for
        // legitimate max-compositions, so feed an artificial sequence by
        // checking the error path with tol < 0 (forces failure).
        let grid = Grid::uniform(0.0, 10.0, 32);
        let fam = vec![Hist::point(&grid, 5.0), Hist::point(&grid, 5.0)];
        // ratios: r(1)=5, r(2)=5/2 — fine normally; with tol=-10 the check
        // trips at k=2 since 2.5 > 5 - 10 is false... instead use tol large
        // negative on an increasing pair via reversed comparison:
        assert!(check_proposition1(&fam, -3.0).is_err());
    }

    #[test]
    fn competitive_ratio_decreases_in_epsilon() {
        let alpha = 0.95;
        let mut prev = f64::INFINITY;
        for &eps in &[0.2, 0.4, 0.6, 0.8] {
            let r = competitive_ratio(eps, alpha, 4.0);
            assert!(r.is_finite() && r > 0.0);
            assert!(r < prev, "ratio must shrink as eps grows");
            prev = r;
        }
    }

    #[test]
    #[should_panic]
    fn competitive_ratio_rejects_small_alpha() {
        // alpha <= 1/(1+eps) invalidates Eq. (40)'s sign argument
        competitive_ratio(0.5, 0.6, 1.0);
    }

    #[test]
    fn ratio_matches_paper_order_of_magnitude() {
        // eps=0.6, alpha→1, C=4: bound should be a small constant factor
        let r = competitive_ratio(0.6, 0.999, 4.0);
        assert!(r > 1.0 && r < 20.0, "r={r}");
    }
}
