//! Testbed task payloads: real compute per task, executed via PJRT.
//!
//! The Spark-on-Yarn mode (Sec 5 analogue) runs one payload execution per
//! simulated "wave" of a task — WordCount maps run the `wordcount`
//! histogram, PageRank shuffles run `pagerank`, Iterative-ML runs
//! `logreg`. Outputs are checked against closed-form expectations so the
//! testbed run doubles as an end-to-end numerical validation of the
//! artifact path.

use anyhow::{anyhow, Result};

use super::pjrt::{exec_f32, literal_f32, literal_i32, Engine};
use crate::util::rng::Rng;
use crate::workload::testbed::AppKind;

/// Compiled payload executables (one per application).
pub struct Payloads {
    wordcount: xla::PjRtLoadedExecutable,
    pagerank: xla::PjRtLoadedExecutable,
    logreg: xla::PjRtLoadedExecutable,
    wc_n: usize,
    wc_vocab: usize,
    pr_n: usize,
    lr_n: usize,
    lr_d: usize,
    /// Executions performed (metrics).
    pub executions: std::sync::atomic::AtomicU64,
}

impl Payloads {
    pub fn new(engine: &Engine) -> Result<Payloads> {
        let a = &engine.artifacts;
        Ok(Payloads {
            wordcount: engine.compile("wordcount")?,
            pagerank: engine.compile("pagerank")?,
            logreg: engine.compile("logreg")?,
            wc_n: a.wc_n,
            wc_vocab: a.wc_vocab,
            pr_n: a.pr_n,
            lr_n: a.lr_n,
            lr_d: a.lr_d,
            executions: std::sync::atomic::AtomicU64::new(0),
        })
    }

    /// Execute the payload for `app`, validating the numerics. Returns a
    /// scalar digest (checksum) so callers can fold it into task output.
    pub fn run(&self, app: AppKind, rng: &mut Rng) -> Result<f64> {
        self.executions
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        match app {
            AppKind::WordCount => {
                let toks: Vec<i32> = (0..self.wc_n)
                    .map(|_| rng.range_u64(0, self.wc_vocab as u64 - 1) as i32)
                    .collect();
                let outs = exec_f32(
                    &self.wordcount,
                    &[literal_i32(&toks, &[self.wc_n as i64])?],
                )?;
                // outs = (hist, checksum): counts must sum to N
                let hist_sum: f32 = outs[0].iter().sum();
                let checksum = outs[1][0];
                if (hist_sum - self.wc_n as f32).abs() > 0.5 {
                    return Err(anyhow!(
                        "wordcount histogram sum {hist_sum} != N {}",
                        self.wc_n
                    ));
                }
                Ok(checksum as f64)
            }
            AppKind::PageRank => {
                let n = self.pr_n;
                let ranks = vec![1.0f32 / n as f32; n];
                let adj: Vec<f32> = (0..n * n)
                    .map(|_| if rng.chance(0.1) { 1.0 } else { 0.0 })
                    .collect();
                let outs = exec_f32(
                    &self.pagerank,
                    &[
                        literal_f32(&ranks, &[n as i64])?,
                        literal_f32(&adj, &[n as i64, n as i64])?,
                    ],
                )?;
                let total: f32 = outs[0].iter().sum();
                // rank mass stays ~1 under the damped update
                if !(0.2..=1.5).contains(&total) {
                    return Err(anyhow!("pagerank mass drifted: {total}"));
                }
                Ok(total as f64)
            }
            AppKind::IterativeMl => {
                let (n, d) = (self.lr_n, self.lr_d);
                let x: Vec<f32> = (0..n * d).map(|_| rng.gauss() as f32).collect();
                let y: Vec<f32> = (0..n)
                    .map(|_| if rng.chance(0.5) { 1.0 } else { 0.0 })
                    .collect();
                let w = vec![0.0f32; d];
                let outs = exec_f32(
                    &self.logreg,
                    &[
                        literal_f32(&x, &[n as i64, d as i64])?,
                        literal_f32(&y, &[n as i64])?,
                        literal_f32(&w, &[d as i64])?,
                    ],
                )?;
                let norm: f32 = outs[0].iter().map(|w| w * w).sum::<f32>().sqrt();
                if !norm.is_finite() {
                    return Err(anyhow!("logreg produced non-finite weights"));
                }
                Ok(norm as f64)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn payloads_run_and_validate() {
        if !std::path::Path::new("artifacts/manifest.toml").exists() {
            eprintln!("skipping: run `make artifacts`");
            return;
        }
        let engine = Engine::new("artifacts").unwrap();
        let p = Payloads::new(&engine).unwrap();
        let mut rng = Rng::new(5);
        for app in AppKind::ALL {
            let digest = p.run(app, &mut rng).unwrap();
            assert!(digest.is_finite(), "{}", app.name());
        }
        assert_eq!(
            p.executions.load(std::sync::atomic::Ordering::Relaxed),
            3
        );
    }
}
