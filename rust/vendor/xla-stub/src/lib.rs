//! API-compatible stand-in for the subset of `xla-rs` the `pingan` crate
//! uses behind its `pjrt` feature.
//!
//! The real bindings link against a native XLA/PJRT build, which the
//! hermetic tier-1 environment does not ship. This stub keeps the gated
//! code *compiling* (so the `pjrt` feature cannot bit-rot) while failing
//! fast at runtime: [`PjRtClient::cpu`] returns an actionable error, so no
//! executable can ever be constructed through the stub. Everything that is
//! reachable without a client — HLO text loading, [`Literal`] construction
//! and reshaping — behaves faithfully.
//!
//! To run real artifacts, replace the `xla` path dependency in
//! `rust/Cargo.toml` with a vendored `xla-rs` checkout; the call sites need
//! no changes.

/// Error type matching the shape of `xla::Error` at the call sites (all of
/// which format it with `{:?}`).
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "{what}: XLA/PJRT is unavailable (this build links the in-tree `xla` stub; \
         vendor xla-rs and update the `xla` path dependency in rust/Cargo.toml \
         to execute HLO artifacts)"
    )))
}

/// Element types a [`Literal`] can hold.
pub trait NativeType: Copy {
    fn vec1(v: &[Self]) -> Literal;
    fn extract(lit: &Literal) -> Result<Vec<Self>>;
}

#[derive(Debug, Clone)]
enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
    Tuple(Vec<Literal>),
}

/// A typed, shaped constant — the input/output unit of PJRT execution.
#[derive(Debug, Clone)]
pub struct Literal {
    data: Data,
    dims: Vec<i64>,
}

impl Literal {
    /// Build a rank-1 literal from a flat slice.
    pub fn vec1<T: NativeType>(v: &[T]) -> Literal {
        T::vec1(v)
    }

    fn element_count(&self) -> usize {
        match &self.data {
            Data::F32(v) => v.len(),
            Data::I32(v) => v.len(),
            Data::Tuple(v) => v.len(),
        }
    }

    /// Reinterpret the literal with new dimensions (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        if n as usize != self.element_count() {
            return Err(Error(format!(
                "reshape {:?} -> {dims:?}: element count mismatch",
                self.dims
            )));
        }
        Ok(Literal {
            data: self.data.clone(),
            dims: dims.to_vec(),
        })
    }

    /// Decompose a tuple literal into its parts.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        match self.data {
            Data::Tuple(parts) => Ok(parts),
            _ => Err(Error("to_tuple on a non-tuple literal".to_string())),
        }
    }

    /// Copy the elements out as a typed vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::extract(self)
    }
}

impl NativeType for f32 {
    fn vec1(v: &[Self]) -> Literal {
        Literal {
            dims: vec![v.len() as i64],
            data: Data::F32(v.to_vec()),
        }
    }

    fn extract(lit: &Literal) -> Result<Vec<Self>> {
        match &lit.data {
            Data::F32(v) => Ok(v.clone()),
            _ => Err(Error("literal is not f32".to_string())),
        }
    }
}

impl NativeType for i32 {
    fn vec1(v: &[Self]) -> Literal {
        Literal {
            dims: vec![v.len() as i64],
            data: Data::I32(v.to_vec()),
        }
    }

    fn extract(lit: &Literal) -> Result<Vec<Self>> {
        match &lit.data {
            Data::I32(v) => Ok(v.clone()),
            _ => Err(Error("literal is not i32".to_string())),
        }
    }
}

/// Parsed (well, carried) HLO module text.
#[derive(Debug, Clone)]
pub struct HloModuleProto {
    text: String,
}

impl HloModuleProto {
    /// Load HLO text from a file. Faithful: only IO can fail here.
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        let text =
            std::fs::read_to_string(path).map_err(|e| Error(format!("reading {path}: {e}")))?;
        Ok(HloModuleProto { text })
    }

    pub fn text(&self) -> &str {
        &self.text
    }
}

/// An XLA computation awaiting compilation.
#[derive(Debug, Clone)]
pub struct XlaComputation {
    _module: HloModuleProto,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation {
            _module: proto.clone(),
        }
    }
}

/// The PJRT client. In the stub, construction always fails — there is no
/// native runtime to hand out.
#[derive(Debug)]
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("PjRtClient::cpu")
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }
}

/// A compiled executable. Unconstructible through the stub (the client
/// cannot be created), but the type and its methods keep callers compiling.
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[Literal]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

/// A device buffer handle returned by execution.
#[derive(Debug)]
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literals_round_trip() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.reshape(&[3]).is_err());
        assert!(l.to_vec::<i32>().is_err());
    }

    #[test]
    fn client_fails_actionably() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(format!("{err:?}").contains("stub"));
    }
}
