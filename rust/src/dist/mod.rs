//! Fixed-grid histogram algebra — the numeric substrate of the insurer.
//!
//! Every estimate the performance modeler serves (Sec 3.2) is a discrete
//! probability distribution over task execution *rates*, held as a pmf on a
//! shared fixed [`Grid`]. The insurer's scoring math is then closed-form
//! over those pmfs:
//!
//! * **bottleneck composition** — a copy's rate is `min(V^P, V^T)` of its
//!   processing-speed and transfer-bandwidth estimates; on a common grid
//!   the pmf of the min of independent variables falls out of a single
//!   backward survival-function pass ([`Hist::min_compose`]).
//! * **multi-source averaging** — a task pulling from several sources sees
//!   the average of the per-source transfer estimates
//!   ([`Hist::average_of`]).
//! * **copy-set scoring** — with `x` copies racing independently, the task
//!   advances at the *fastest* copy's rate; `E[r(x)]` is the expectation of
//!   the max, computed from the product of the copies' CDFs
//!   ([`Hist::expected_max`]) — the E\[max\]-of-replicas analysis that
//!   Algorithm 1 greedily maximizes round by round.
//! * **observation absorption** — the modeler folds each finished task's
//!   report into its estimate as a recency-weighted mixture
//!   ([`Hist::blend`]).
//!
//! Independence across copies and across the (proc, trans) pair is assumed
//! throughout, as documented in `perfmodel::modeler`. Conventions shared
//! with the batched scorer (`runtime::scorer::CpuScorer`) and the L1
//! Pallas kernel (`python/compile/kernels/expmax.py`), which this module
//! is cross-checked against bin-for-bin:
//!
//! * pmfs are indexed by grid bin and always sum to 1 (constructors and
//!   compositions renormalize);
//! * bin `j` represents the rate value `Grid::value(j)`; centers span
//!   `[lo, hi]` inclusive with uniform spacing;
//! * expectations are pmf-weighted sums of bin values.

mod grid;
mod hist;

pub use grid::Grid;
pub use hist::Hist;
