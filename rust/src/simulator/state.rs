//! Runtime state of jobs, tasks and copies inside a simulation.

use crate::workload::job::JobSpec;

/// Lifecycle of a task.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TaskState {
    /// Dependencies incomplete.
    Blocked,
    /// Runnable, no copy launched yet (or all copies died).
    Ready,
    /// At least one alive copy.
    Running,
    Done,
}

/// One launched copy of a task.
///
/// Copies progress at a piecewise-constant `rate`: after the progress
/// phase of slot `t ≥ rate_since` a copy has processed `progress_base +
/// rate · (t - rate_since + 1)` data units — the event-skip engine
/// exploits that closed form to predict completions
/// ([`CopyRt::completion_slot`]) and to sync `processed` lazily when it
/// jumps `now`. Under [`crate::config::spec::BandwidthModel::Constant`]
/// the rate never changes (`progress_base` stays 0, `rate_since` stays
/// `launched_at`, making the closed form the familiar `rate · (t -
/// launched_at + 1)` — bit for bit). Under `Shared`, a fair-share
/// re-rate at the policy-epoch barrier checkpoints `processed` into
/// `progress_base`, restarts `rate_since`, and swaps in the new rate.
#[derive(Clone, Debug)]
pub struct CopyRt {
    pub cluster: usize,
    /// Current execution rate (data units per slot) — min(V^P, V^T)
    /// drawn at launch; under the shared bandwidth model, re-rated down
    /// by the fair-share solver when the WAN contends.
    pub rate: f64,
    /// The processing-speed component of the draw (logged to the modeler).
    pub proc_speed: f64,
    /// The transfer-bandwidth component (logged per source pair).
    pub trans_speed: f64,
    /// Data processed so far.
    pub processed: f64,
    pub launched_at: u64,
    /// `processed` checkpoint at the start of the current rate segment
    /// (0 until the first re-rate).
    pub progress_base: f64,
    /// First slot of the current rate segment (`launched_at` until the
    /// first re-rate).
    pub rate_since: u64,
    /// Handle of this copy's transfer in the fair-share solver (`None`
    /// under the constant model or when all inputs are local).
    pub bw_id: Option<u64>,
    pub alive: bool,
    /// Bandwidth this copy reserves on its cluster's ingress at launch
    /// (0 if all inputs local). Admission-control ledger state — under
    /// the shared model the solver owns the *actual* contended rate.
    pub ingress_bw: f64,
    /// (source cluster, egress bandwidth reserved) pairs.
    pub egress_bw: Vec<(usize, f64)>,
}

impl CopyRt {
    /// The slot whose progress phase finishes `datasize` on this copy:
    /// the first `t` with `progress_base + rate · (t - rate_since + 1) ≥
    /// datasize`.
    pub fn completion_slot(&self, datasize: f64) -> u64 {
        let remaining = (datasize - self.progress_base).max(0.0);
        let k = (remaining / self.rate.max(1e-12)).ceil().max(1.0);
        // the segment's first slot already counts one progress increment
        self.rate_since + (k as u64) - 1
    }
}

/// Runtime state of one task.
#[derive(Clone, Debug)]
pub struct TaskRt {
    pub state: TaskState,
    pub copies: Vec<CopyRt>,
    /// Resolved input clusters: raw locations plus producers' output sites.
    pub sources: Vec<usize>,
    pub n_deps_left: usize,
    pub done_at: Option<u64>,
    /// Cluster of the winning copy.
    pub output_cluster: Option<usize>,
    pub ready_at: Option<u64>,
}

impl TaskRt {
    pub fn alive_copies(&self) -> usize {
        self.copies.iter().filter(|c| c.alive).count()
    }

    /// Clusters already hosting an alive copy.
    pub fn copy_clusters(&self) -> Vec<usize> {
        self.copies
            .iter()
            .filter(|c| c.alive)
            .map(|c| c.cluster)
            .collect()
    }

    /// Earliest completion slot over alive copies (closed form; `None`
    /// when no copy is alive). The event-skip engine schedules one
    /// `CopyCompletion` event here per copy-set epoch.
    pub fn next_completion_slot(&self, datasize: f64) -> Option<u64> {
        self.copies
            .iter()
            .filter(|c| c.alive)
            .map(|c| c.completion_slot(datasize))
            .min()
    }

    /// Max processed over alive copies (for progress/unprocessed metrics).
    pub fn max_processed(&self) -> f64 {
        self.copies
            .iter()
            .filter(|c| c.alive)
            .map(|c| c.processed)
            .fold(0.0, f64::max)
    }
}

/// Runtime state of one job.
#[derive(Clone, Debug)]
pub struct JobRt {
    pub spec: JobSpec,
    pub tasks: Vec<TaskRt>,
    pub arrived: bool,
    pub done_at: Option<u64>,
}

impl JobRt {
    pub fn new(spec: JobSpec) -> JobRt {
        let tasks = spec
            .tasks
            .iter()
            .map(|t| TaskRt {
                state: if t.deps.is_empty() {
                    TaskState::Ready
                } else {
                    TaskState::Blocked
                },
                copies: Vec::new(),
                sources: t.input_locations.clone(),
                n_deps_left: t.deps.len(),
                done_at: None,
                output_cluster: None,
                ready_at: if t.deps.is_empty() { Some(spec.arrival) } else { None },
            })
            .collect();
        JobRt {
            spec,
            tasks,
            arrived: false,
            done_at: None,
        }
    }

    pub fn is_done(&self) -> bool {
        self.done_at.is_some()
    }

    pub fn alive_at(&self, now: u64) -> bool {
        self.spec.arrival <= now && !self.is_done()
    }

    /// Unprocessed data of the *current frontier* (ready + running tasks) —
    /// the paper's job-priority key ("unprocessed data size of its current
    /// stage"; no a-priori knowledge of future stages is used).
    pub fn unprocessed(&self) -> f64 {
        self.spec
            .tasks
            .iter()
            .zip(&self.tasks)
            .filter(|(_, rt)| matches!(rt.state, TaskState::Ready | TaskState::Running))
            .map(|(spec, rt)| (spec.datasize - rt.max_processed()).max(0.0))
            .sum()
    }

    pub fn n_done(&self) -> usize {
        self.tasks
            .iter()
            .filter(|t| t.state == TaskState::Done)
            .count()
    }

    pub fn flowtime(&self) -> Option<u64> {
        self.done_at.map(|f| f.saturating_sub(self.spec.arrival))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::job::{OpKind, TaskSpec};

    fn chain_job() -> JobRt {
        JobRt::new(JobSpec {
            id: 0,
            name: "chain".into(),
            arrival: 5,
            tasks: vec![
                TaskSpec {
                    idx: 0,
                    op: OpKind::Map,
                    datasize: 10.0,
                    deps: vec![],
                    input_locations: vec![1],
                },
                TaskSpec {
                    idx: 1,
                    op: OpKind::Reduce,
                    datasize: 4.0,
                    deps: vec![0],
                    input_locations: vec![],
                },
            ],
        })
    }

    #[test]
    fn initial_states() {
        let j = chain_job();
        assert_eq!(j.tasks[0].state, TaskState::Ready);
        assert_eq!(j.tasks[1].state, TaskState::Blocked);
        assert_eq!(j.tasks[1].n_deps_left, 1);
        assert!(!j.is_done());
        assert!((j.unprocessed() - 10.0).abs() < 1e-12); // frontier only
    }

    #[test]
    fn alive_window() {
        let j = chain_job();
        assert!(!j.alive_at(4));
        assert!(j.alive_at(5));
    }

    #[test]
    fn flowtime_after_done() {
        let mut j = chain_job();
        assert_eq!(j.flowtime(), None);
        j.done_at = Some(25);
        assert_eq!(j.flowtime(), Some(20));
    }

    #[test]
    fn copy_bookkeeping() {
        let mut t = chain_job().tasks.remove(0);
        assert_eq!(t.alive_copies(), 0);
        t.copies.push(CopyRt {
            cluster: 3,
            rate: 2.0,
            proc_speed: 2.5,
            trans_speed: 2.0,
            processed: 1.0,
            launched_at: 0,
            progress_base: 0.0,
            rate_since: 0,
            bw_id: None,
            alive: true,
            ingress_bw: 2.0,
            egress_bw: vec![(1, 2.0)],
        });
        assert_eq!(t.alive_copies(), 1);
        assert_eq!(t.copy_clusters(), vec![3]);
        assert!((t.max_processed() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn completion_slot_closed_form() {
        let c = CopyRt {
            cluster: 0,
            rate: 4.0,
            proc_speed: 4.0,
            trans_speed: 4.0,
            processed: 0.0,
            launched_at: 10,
            progress_base: 0.0,
            rate_since: 10,
            bw_id: None,
            alive: true,
            ingress_bw: 0.0,
            egress_bw: vec![],
        };
        // 10 units at rate 4: slots 10, 11, 12 → done in slot 12
        assert_eq!(c.completion_slot(10.0), 12);
        // exact multiple: 8 units in slots 10, 11
        assert_eq!(c.completion_slot(8.0), 11);
        // sub-slot work still takes the launch slot
        assert_eq!(c.completion_slot(0.5), 10);
    }

    #[test]
    fn completion_slot_respects_rate_segments() {
        // launched at 10 with rate 4, re-rated to 1.0 at slot 13 having
        // banked 8 of 10 units: 2 remain → slots 13, 14 → done in 14
        let c = CopyRt {
            cluster: 0,
            rate: 1.0,
            proc_speed: 4.0,
            trans_speed: 4.0,
            processed: 8.0,
            launched_at: 10,
            progress_base: 8.0,
            rate_since: 13,
            bw_id: Some(0),
            alive: true,
            ingress_bw: 0.0,
            egress_bw: vec![],
        };
        assert_eq!(c.completion_slot(10.0), 14);
        // already-banked work completes in the segment's first slot
        assert_eq!(c.completion_slot(8.0), 13);
    }

    #[test]
    fn next_completion_takes_the_fastest_alive_copy() {
        let mut t = chain_job().tasks.remove(0);
        assert_eq!(t.next_completion_slot(10.0), None);
        for (rate, launched_at, alive) in [(1.0, 0, true), (5.0, 2, true), (50.0, 1, false)] {
            t.copies.push(CopyRt {
                cluster: 0,
                rate,
                proc_speed: rate,
                trans_speed: rate,
                processed: 0.0,
                launched_at,
                progress_base: 0.0,
                rate_since: launched_at,
                bw_id: None,
                alive,
                ingress_bw: 0.0,
                egress_bw: vec![],
            });
        }
        // slow copy: slot 9; fast copy: 2 + ceil(10/5) - 1 = 3; dead: ignored
        assert_eq!(t.next_completion_slot(10.0), Some(3));
    }
}
