//! Tiny JSON emitter and parser (serde is unavailable offline).
//!
//! Experiment drivers dump their series as JSON so EXPERIMENTS.md numbers are
//! regenerable and diffable. Emission is the hot direction — configs are
//! TOML (see `config::toml`), results are JSON. [`Json::parse`] exists for
//! the few read paths (`pingan bench-append` ingesting CI artifacts): a
//! strict recursive-descent reader over the same value model, so anything
//! this module emits parses back to an equal tree.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. `BTreeMap` keeps key order deterministic across runs.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn set(&mut self, key: &str, val: Json) -> &mut Self {
        if let Json::Obj(m) = self {
            m.insert(key.to_string(), val);
        } else {
            panic!("Json::set on non-object");
        }
        self
    }

    pub fn num(x: f64) -> Json {
        Json::Num(x)
    }

    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }

    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    pub fn arr_str(xs: &[String]) -> Json {
        Json::Arr(xs.iter().map(|x| Json::Str(x.clone())).collect())
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    /// Serialize with 2-space indentation and a trailing newline — for
    /// repo-tracked, hand-diffed files (`pingan bench-append` rewriting
    /// BENCH_sim.json). Scalars render exactly as in [`Json::to_string`];
    /// note `Obj` keys always emit in sorted (`BTreeMap`) order.
    pub fn to_pretty(&self) -> String {
        let mut s = String::new();
        self.write_pretty(&mut s, 0);
        s.push('\n');
        s
    }

    fn write_pretty(&self, out: &mut String, depth: usize) {
        fn pad(out: &mut String, depth: usize) {
            for _ in 0..depth {
                out.push_str("  ");
            }
        }
        match self {
            Json::Arr(xs) if !xs.is_empty() => {
                out.push_str("[\n");
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    pad(out, depth + 1);
                    x.write_pretty(out, depth + 1);
                }
                out.push('\n');
                pad(out, depth);
                out.push(']');
            }
            Json::Obj(m) if !m.is_empty() => {
                out.push_str("{\n");
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    pad(out, depth + 1);
                    Json::Str(k.clone()).write(out);
                    out.push_str(": ");
                    v.write_pretty(out, depth + 1);
                }
                out.push('\n');
                pad(out, depth);
                out.push('}');
            }
            leaf => leaf.write(out),
        }
    }

    /// Object field lookup (`None` on non-objects and missing keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_num(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(xs) => Some(xs),
            _ => None,
        }
    }

    /// Strict parse of one JSON document (no trailing garbage). Numbers
    /// land as `f64` like everything else in this model; since the
    /// emitter writes integers without a fraction, emit→parse→emit is
    /// byte-stable for the documents this repo produces.
    pub fn parse(text: &str) -> Result<Json, String> {
        let mut p = Parser {
            b: text.as_bytes(),
            i: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.is_finite() {
                    if *x == x.trunc() && x.abs() < 1e15 {
                        let _ = write!(out, "{}", *x as i64);
                    } else {
                        let _ = write!(out, "{x}");
                    }
                } else {
                    out.push_str("null"); // JSON has no NaN/Inf
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(xs) => {
                out.push('[');
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Recursive-descent state for [`Json::parse`]: a byte cursor (JSON
/// syntax is ASCII; string contents pass through as UTF-8).
struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl Parser<'_> {
    fn skip_ws(&mut self) {
        while let Some(&c) = self.b.get(self.i) {
            if c == b' ' || c == b'\t' || c == b'\n' || c == b'\r' {
                self.i += 1;
            } else {
                break;
            }
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.b.get(self.i) == Some(&c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected `{}` at byte {}", c as char, self.i))
        }
    }

    fn literal(&mut self, word: &str, val: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(val)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.b.get(self.i) {
            None => Err("unexpected end of input".to_string()),
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => {
                self.i += 1;
                let mut xs = Vec::new();
                self.skip_ws();
                if self.b.get(self.i) == Some(&b']') {
                    self.i += 1;
                    return Ok(Json::Arr(xs));
                }
                loop {
                    self.skip_ws();
                    xs.push(self.value()?);
                    self.skip_ws();
                    match self.b.get(self.i) {
                        Some(b',') => self.i += 1,
                        Some(b']') => {
                            self.i += 1;
                            return Ok(Json::Arr(xs));
                        }
                        _ => return Err(format!("expected `,` or `]` at byte {}", self.i)),
                    }
                }
            }
            Some(b'{') => {
                self.i += 1;
                let mut m = BTreeMap::new();
                self.skip_ws();
                if self.b.get(self.i) == Some(&b'}') {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                loop {
                    self.skip_ws();
                    let k = self.string()?;
                    self.skip_ws();
                    self.expect(b':')?;
                    self.skip_ws();
                    m.insert(k, self.value()?);
                    self.skip_ws();
                    match self.b.get(self.i) {
                        Some(b',') => self.i += 1,
                        Some(b'}') => {
                            self.i += 1;
                            return Ok(Json::Obj(m));
                        }
                        _ => return Err(format!("expected `,` or `}}` at byte {}", self.i)),
                    }
                }
            }
            Some(_) => self.number(),
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        while let Some(&c) = self.b.get(self.i) {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.i += 1;
            } else {
                break;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| format!("bad number `{s}` at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.b.get(self.i) {
                None => return Err("unterminated string".to_string()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.b.get(self.i) {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let cp = self.hex4()?;
                            // surrogate pair: a high half must be followed
                            // by an escaped low half
                            let c = if (0xD800..0xDC00).contains(&cp) {
                                self.i += 1;
                                if self.b.get(self.i) != Some(&b'\\')
                                    || self.b.get(self.i + 1) != Some(&b'u')
                                {
                                    return Err("lone high surrogate".to_string());
                                }
                                self.i += 2;
                                let lo = self.hex4()?;
                                if !(0xDC00..0xE000).contains(&lo) {
                                    return Err("bad low surrogate".to_string());
                                }
                                0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00)
                            } else {
                                cp
                            };
                            out.push(
                                char::from_u32(c)
                                    .ok_or_else(|| format!("bad codepoint U+{c:04X}"))?,
                            );
                            // hex4 leaves the cursor ON the last hex digit;
                            // the common path below advances past it
                        }
                        _ => return Err(format!("bad escape at byte {}", self.i)),
                    }
                    self.i += 1;
                }
                Some(&c) if c < 0x80 => {
                    if c < 0x20 {
                        return Err(format!("raw control byte at {}", self.i));
                    }
                    out.push(c as char);
                    self.i += 1;
                }
                Some(_) => {
                    // multi-byte UTF-8: copy the whole scalar through
                    let s = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| "invalid utf-8 in string".to_string())?;
                    let ch = s.chars().next().unwrap();
                    out.push(ch);
                    self.i += ch.len_utf8();
                }
            }
        }
    }

    /// Read 4 hex digits after `\u`, leaving the cursor on the last one
    /// (the caller's shared `+= 1` then steps past it).
    fn hex4(&mut self) -> Result<u32, String> {
        let start = self.i + 1;
        let end = start + 4;
        if end > self.b.len() {
            return Err("truncated \\u escape".to_string());
        }
        let s = std::str::from_utf8(&self.b[start..end]).map_err(|_| "bad \\u escape")?;
        let v = u32::from_str_radix(s, 16).map_err(|_| format!("bad \\u escape `{s}`"))?;
        self.i = end - 1;
        Ok(v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_object() {
        let mut j = Json::obj();
        j.set("name", Json::str("fig4"))
            .set("values", Json::arr_f64(&[1.0, 2.5]))
            .set("ok", Json::Bool(true));
        assert_eq!(
            j.to_string(),
            r#"{"name":"fig4","ok":true,"values":[1,2.5]}"#
        );
    }

    #[test]
    fn escapes() {
        assert_eq!(Json::str("a\"b\n").to_string(), r#""a\"b\n""#);
    }

    #[test]
    fn nan_becomes_null() {
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
    }

    #[test]
    fn integers_render_without_fraction() {
        assert_eq!(Json::Num(42.0).to_string(), "42");
        assert_eq!(Json::Num(0.125).to_string(), "0.125");
    }

    #[test]
    fn pretty_round_trips_and_indents() {
        let mut j = Json::obj();
        j.set("history", Json::Arr(vec![Json::num(1.0)]))
            .set("what", Json::str("x"))
            .set("empty", Json::obj())
            .set("none", Json::Arr(vec![]));
        let pretty = j.to_pretty();
        assert!(pretty.ends_with("}\n"));
        assert!(pretty.contains("  \"history\": [\n    1\n  ]"));
        assert!(pretty.contains("\"empty\": {}"));
        assert!(pretty.contains("\"none\": []"));
        assert_eq!(Json::parse(&pretty).unwrap(), j);
    }

    #[test]
    fn parse_round_trips_emitted_documents() {
        let mut j = Json::obj();
        j.set("commit", Json::str("abc123"))
            .set("cases", Json::Arr(vec![Json::str("a\"b\n"), Json::num(1.5)]))
            .set("ok", Json::Bool(true))
            .set("none", Json::Null)
            .set("n", Json::num(-42.0));
        let text = j.to_string();
        let back = Json::parse(&text).unwrap();
        assert_eq!(back, j);
        assert_eq!(back.to_string(), text);
    }

    #[test]
    fn parse_handles_whitespace_escapes_and_unicode() {
        let j = Json::parse(" { \"a\" : [ 1 , 2.5e1 , \"x\\u00e9y\" ] , \"b\" : { } } ").unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(j.get("a").unwrap().as_arr().unwrap()[1].as_num(), Some(25.0));
        assert_eq!(
            j.get("a").unwrap().as_arr().unwrap()[2].as_str(),
            Some("xéy")
        );
        assert_eq!(j.get("b"), Some(&Json::obj()));
        // surrogate pair
        assert_eq!(
            Json::parse("\"\\ud83d\\ude00\"").unwrap().as_str(),
            Some("😀")
        );
        // raw multi-byte UTF-8 passes through
        assert_eq!(Json::parse("\"héllo\"").unwrap().as_str(), Some("héllo"));
    }

    #[test]
    fn parse_rejects_malformed_documents() {
        for bad in [
            "", "{", "[1,", "{\"a\":}", "nul", "01x", "\"unterminated",
            "{\"a\":1} trailing", "\"\\ud83d\"", "\"\\q\"",
        ] {
            assert!(Json::parse(bad).is_err(), "`{bad}` should fail");
        }
    }

    #[test]
    fn accessors_navigate_objects() {
        let j = Json::parse(r#"{"commit":"deadbeef","cases":[{"name":"x"}]}"#).unwrap();
        assert_eq!(j.get("commit").unwrap().as_str(), Some("deadbeef"));
        let cases = j.get("cases").unwrap().as_arr().unwrap();
        assert_eq!(cases[0].get("name").unwrap().as_str(), Some("x"));
        assert_eq!(j.get("missing"), None);
        assert_eq!(Json::Null.get("x"), None);
        assert_eq!(Json::Bool(true).as_str(), None);
    }
}
