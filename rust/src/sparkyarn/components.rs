//! Fig-1 control-plane components.
//!
//! These mirror the paper's workflow: DAGScheduler (inside each job's
//! AppMaster) emits TaskSets annotated with data locations from the
//! OutputRecorder; TaskSets queue in the TaskSetPool in ascending order of
//! unprocessed datasize; the Insurancer drains the pool and produces an
//! insurance plan; AppMasters turn the plan into container requests
//! against the per-cluster ResourceManagers.

use crate::simulator::state::{JobRt, TaskState};

/// A TaskSet: one job's currently-ready tasks plus its priority key.
#[derive(Clone, Debug)]
pub struct TaskSet {
    pub job: usize,
    pub tasks: Vec<usize>,
    /// Unprocessed datasize of the job's frontier (priority key).
    pub unprocessed: f64,
}

/// The TaskSetPool: TaskSets queued in ascending unprocessed-datasize order
/// (workflow step b).
#[derive(Clone, Debug, Default)]
pub struct TaskSetPool {
    sets: Vec<TaskSet>,
}

impl TaskSetPool {
    pub fn new() -> TaskSetPool {
        TaskSetPool::default()
    }

    pub fn submit(&mut self, set: TaskSet) {
        self.sets.push(set);
    }

    /// Drain in priority order for the insurer.
    pub fn drain_ordered(&mut self) -> Vec<TaskSet> {
        self.sets.sort_by(|a, b| {
            a.unprocessed
                .partial_cmp(&b.unprocessed)
                .unwrap()
                .then(a.job.cmp(&b.job))
        });
        std::mem::take(&mut self.sets)
    }

    pub fn len(&self) -> usize {
        self.sets.len()
    }

    pub fn is_empty(&self) -> bool {
        self.sets.is_empty()
    }
}

/// Per-cluster container ledger (one RM manages a group of clusters in the
/// paper's deployment; the ledger is per cluster either way).
#[derive(Clone, Debug)]
pub struct ResourceManager {
    pub cluster: usize,
    pub capacity: usize,
    pub granted: usize,
    /// Containers handed out over the lifetime (diagnostics).
    pub total_grants: u64,
}

impl ResourceManager {
    pub fn new(cluster: usize, capacity: usize) -> ResourceManager {
        ResourceManager {
            cluster,
            capacity,
            granted: 0,
            total_grants: 0,
        }
    }

    /// Grant one container if capacity allows.
    pub fn try_grant(&mut self) -> bool {
        if self.granted < self.capacity {
            self.granted += 1;
            self.total_grants += 1;
            true
        } else {
            false
        }
    }

    pub fn release(&mut self) {
        debug_assert!(self.granted > 0, "release without grant");
        self.granted = self.granted.saturating_sub(1);
    }

    pub fn free(&self) -> usize {
        self.capacity - self.granted
    }
}

/// AppMaster: one per job. Wraps the DAGScheduler view over the job's
/// runtime state and emits TaskSets (workflow step a/b).
pub struct AppMaster {
    pub job: usize,
}

impl AppMaster {
    pub fn new(job: usize) -> AppMaster {
        AppMaster { job }
    }

    /// DAGScheduler: collect ready tasks (deps satisfied, no alive copy),
    /// with data locations already resolved in `JobRt::tasks[].sources`
    /// (the OutputRecorder writes producer locations there on completion).
    pub fn emit_taskset(&self, rt: &JobRt) -> Option<TaskSet> {
        let tasks: Vec<usize> = rt
            .tasks
            .iter()
            .enumerate()
            .filter(|(_, t)| t.state == TaskState::Ready && t.alive_copies() == 0)
            .map(|(i, _)| i)
            .collect();
        if tasks.is_empty() {
            None
        } else {
            Some(TaskSet {
                job: self.job,
                tasks,
                unprocessed: rt.unprocessed(),
            })
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::job::{JobSpec, OpKind, TaskSpec};

    fn job(id: usize, sizes: &[f64]) -> JobRt {
        JobRt::new(JobSpec {
            id,
            name: format!("j{id}"),
            arrival: 0,
            tasks: sizes
                .iter()
                .enumerate()
                .map(|(i, &d)| TaskSpec {
                    idx: i,
                    op: OpKind::Map,
                    datasize: d,
                    deps: vec![],
                    input_locations: vec![0],
                })
                .collect(),
        })
    }

    #[test]
    fn pool_orders_by_unprocessed() {
        let mut pool = TaskSetPool::new();
        pool.submit(TaskSet {
            job: 1,
            tasks: vec![0],
            unprocessed: 100.0,
        });
        pool.submit(TaskSet {
            job: 2,
            tasks: vec![0],
            unprocessed: 10.0,
        });
        pool.submit(TaskSet {
            job: 3,
            tasks: vec![0],
            unprocessed: 50.0,
        });
        let order: Vec<usize> = pool.drain_ordered().iter().map(|s| s.job).collect();
        assert_eq!(order, vec![2, 3, 1]);
        assert!(pool.is_empty());
    }

    #[test]
    fn rm_capacity_enforced() {
        let mut rm = ResourceManager::new(0, 2);
        assert!(rm.try_grant());
        assert!(rm.try_grant());
        assert!(!rm.try_grant());
        assert_eq!(rm.free(), 0);
        rm.release();
        assert_eq!(rm.free(), 1);
        assert_eq!(rm.total_grants, 2);
    }

    #[test]
    fn appmaster_emits_ready_tasks_only() {
        let rt = job(7, &[10.0, 20.0]);
        let am = AppMaster::new(7);
        let ts = am.emit_taskset(&rt).unwrap();
        assert_eq!(ts.job, 7);
        assert_eq!(ts.tasks, vec![0, 1]);
        assert!((ts.unprocessed - 30.0).abs() < 1e-12);
    }
}
