//! Aligned ASCII table rendering for figure/table harness output.
//!
//! Every experiment driver prints the same rows/series the paper reports;
//! this formatter keeps those dumps readable and diff-stable.

#[derive(Clone, Debug, Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
    title: String,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Self {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            title: title.to_string(),
        }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row arity mismatch in table `{}`",
            self.title
        );
        self.rows.push(cells.to_vec());
        self
    }

    pub fn row_strs(&mut self, cells: &[&str]) -> &mut Self {
        let v: Vec<String> = cells.iter().map(|s| s.to_string()).collect();
        self.row(&v)
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("== {} ==\n", self.title));
        }
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::new();
            for (i, c) in cells.iter().enumerate() {
                if i > 0 {
                    line.push_str("  ");
                }
                line.push_str(&format!("{:<width$}", c, width = widths[i]));
            }
            line.trim_end().to_string()
        };
        out.push_str(&fmt_row(&self.header, &widths));
        out.push('\n');
        let total: usize = widths.iter().sum::<usize>() + 2 * (widths.len().saturating_sub(1));
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }
}

/// Format a float with fixed decimals — the standard cell maker.
pub fn fnum(x: f64, decimals: usize) -> String {
    format!("{:.*}", decimals, x)
}

/// Percent formatting, e.g. 0.396 -> "39.6%".
pub fn fpct(x: f64) -> String {
    format!("{:.1}%", 100.0 * x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo", &["scheduler", "avg flowtime (s)"]);
        t.row_strs(&["pingan", "123.4"]);
        t.row_strs(&["spark", "204.1"]);
        let s = t.render();
        assert!(s.contains("== demo =="));
        assert!(s.contains("scheduler"));
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 5);
    }

    #[test]
    #[should_panic]
    fn arity_mismatch_panics() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row_strs(&["only-one"]);
    }

    #[test]
    fn formats() {
        assert_eq!(fnum(1.23456, 2), "1.23");
        assert_eq!(fpct(0.396), "39.6%");
    }
}
